"""Memory-governed data plane: sizeof accounting, distributed
ref-counting GC, bounded-store LRU eviction with lineage
reconstruction, memory-aware placement, wipe/transfer races, DES store
occupancy, and the profiler's eviction/reclaim counters."""
import threading
import time

import pytest

from repro import core
from repro.core.control_plane import ControlPlane
from repro.core.object_store import MISSING, ObjectStore
from repro.core.profiler import summarize
from repro.core.simulator import ClusterSim, SimCosts, SimTask


@pytest.fixture()
def cluster():
    c = core.init(num_nodes=2, workers_per_node=2, spill_threshold=4096)
    yield c
    core.shutdown()


@core.remote
def blob(i, nbytes=1024):
    return bytes([i % 251]) * nbytes


# ------------------------------------------------------------- accounting


def test_bytes_of_stored_none_is_nonzero():
    gcs = ControlPlane(2)
    store = ObjectStore(0, gcs)
    store.put("x", None)
    assert store.bytes_of("x") > 0          # a stored None is an object...
    assert store.bytes_of("absent") == 0    # ...a missing one is absence
    assert store.get_if_present("x") is None
    assert store.get_if_present("absent") is MISSING


def test_sizeof_accounting_tracks_puts_and_discards():
    gcs = ControlPlane(2)
    store = ObjectStore(0, gcs)
    store.put("a", bytes(5000))
    assert store.used_bytes >= 5000
    assert store.bytes_of("a") >= 5000
    store.put("a", bytes(100))              # overwrite re-accounts
    assert store.used_bytes < 5000
    store.discard("a")
    assert store.used_bytes == 0
    assert not gcs.locations("a")


def test_sizeof_containers_and_arrays():
    import numpy as np
    assert core.sizeof(np.zeros(1000, dtype=np.float32)) >= 4000
    assert core.sizeof(None) > 0
    assert core.sizeof([bytes(100)] * 10) >= 1000


# ----------------------------------------------------------- refcount GC


def test_dropped_driver_ref_reclaimed_cluster_wide(cluster):
    ref = blob.submit(7)
    assert core.get(ref)[:1] == bytes([7])
    oid = ref.id
    assert cluster.gcs.refcount(oid) == 1
    del ref
    assert cluster.memory.wait_reclaimed(oid, timeout=5.0)
    assert not cluster.gcs.locations(oid)
    assert all(not n.store.contains(oid) for n in cluster.nodes)


def test_arg_borrow_pins_until_consumer_done(cluster):
    gate = threading.Event()

    @core.remote
    def gated(x):
        gate.wait(5.0)
        return len(x)

    a = core.put(bytes(2048))
    oid = a.id
    out = gated.submit(a)
    del a                     # count drops to zero, but the task pins it
    time.sleep(0.1)
    assert cluster.memory.quiesce(5.0)
    assert cluster.gcs.locations(oid)       # still resident: pinned
    gate.set()
    assert core.get(out) == 2048
    # consumer done -> unpinned -> reclaimed
    assert cluster.memory.wait_reclaimed(oid, timeout=5.0)
    assert not cluster.gcs.locations(oid)


def test_task_spec_holds_borrows_not_owners(cluster):
    a = core.put(bytes(128))
    oid = a.id

    @core.remote
    def ident(x):
        return x

    out = ident.submit(a)
    assert core.get(out) == bytes(128)
    # the spec in the task table references `a` — but as a borrow, so
    # dropping the driver handle must still reach count zero
    del a
    assert cluster.memory.wait_reclaimed(oid, timeout=5.0), \
        "task-table spec kept an owning handle alive"


def test_fire_and_forget_output_is_collected(cluster):
    oid = blob.submit(3).id   # handle dropped immediately
    deadline = time.perf_counter() + 5.0
    while time.perf_counter() < deadline:
        if cluster.gcs.is_freed(oid):
            break
        time.sleep(0.01)
    assert cluster.gcs.is_freed(oid)
    assert not cluster.gcs.locations(oid)


def test_free_is_prompt_and_counts_as_done(cluster):
    ref = core.put(bytes(4096))
    core.free(ref)
    done, pending = core.wait([ref], num_returns=1, timeout=1.0)
    assert done and not pending
    t0 = time.perf_counter()
    with pytest.raises(core.ObjectReclaimedError):
        core.get(ref, timeout=10.0)
    assert time.perf_counter() - t0 < 5.0   # prompt, not a timeout


def test_free_wakes_already_blocked_wait(cluster):
    # a future whose producing task will never run (parked on a
    # resource no node has): a blocked wait() must not sleep to its
    # timeout once free() discards the future — the freed state is
    # pushed over the completion-notify channel
    @core.remote(resources={"tpu": 8.0})
    def never():
        return 1

    ref = never.submit()
    results = {}

    def waiter():
        results["wait"] = core.wait([ref], num_returns=1, timeout=30.0)

    tw = threading.Thread(target=waiter)
    tw.start()
    time.sleep(0.2)           # parked on the notify channel now
    t0 = time.perf_counter()
    core.free(ref)
    tw.join(10.0)
    assert not tw.is_alive() and time.perf_counter() - t0 < 5.0, \
        "free() did not wake the blocked wait()"
    done, pending = results["wait"]
    assert done and not pending           # freed future counts as done


def test_concurrent_eviction_keeps_one_replica_of_put_object():
    c = core.init(num_nodes=2, workers_per_node=2, spill_threshold=4096,
                  store_capacity_bytes=16 * 1024)
    try:
        h = core.ObjectRef("dual")
        c.memory.adopt(h)
        c.nodes[0].store.put("dual", bytes(4096))
        c.nodes[1].store.fetch_from(c.nodes[0].store, "dual")
        # pressure BOTH stores simultaneously: the asymmetric replica
        # rule must leave the lowest-id copy standing even though each
        # side sees "another replica exists" at classification time
        pins = []
        for i in range(6):
            for nd in c.nodes:
                f = core.ObjectRef(f"pin{nd.node_id}-{i}")
                c.memory.adopt(f)
                pins.append(f)
                nd.store.put(f"pin{nd.node_id}-{i}", bytes(4096))
        assert c.nodes[0].store.contains("dual"), \
            "both replicas of an unreconstructable object were evicted"
        del h, pins
    finally:
        core.shutdown()


# ------------------------------------------------- eviction + reconstruct


def test_evicted_then_refetched_reconstructs_via_lineage():
    c = core.init(num_nodes=2, workers_per_node=2, spill_threshold=4096,
                  store_capacity_bytes=32 * 1024)
    try:
        keep = blob.submit(5, 4096)
        assert core.get(keep)[:1] == bytes([5])
        (nid,) = list(c.gcs.locations(keep.id))[:1]
        node = c.nodes[nid]
        # fill the owning node with protected residents (adopted handles,
        # no lineage) until `keep` — referenced but reconstructible — is
        # the eviction candidate and gets dropped
        fillers = []
        for i in range(8):   # 32 KB protected + 4 KB keep > 32 KB cap
                             # (accounting is exact now: 8x4096 fills
                             # the capacity to the byte)
            h = core.ObjectRef(f"fill{i}")
            c.memory.adopt(h)
            fillers.append(h)
            node.store.put(f"fill{i}", bytes(4096))
        assert not node.store.contains(keep.id)
        assert node.store.used_bytes <= 32 * 1024
        # transparent repair on refetch
        assert core.get(keep) == bytes([5]) * 4096
        s = summarize(c.gcs)
        assert s["evictions"] >= 1
        assert s["reconstruct_after_evict"] >= 1
        assert s["bytes_freed"] > 0
        del fillers
    finally:
        core.shutdown()


def test_eviction_prefers_secondary_replica():
    c = core.init(num_nodes=2, workers_per_node=2, spill_threshold=4096,
                  store_capacity_bytes=16 * 1024)
    try:
        # primary on node0, replica on node1; both referenced
        h = core.ObjectRef("obj-rep")
        c.memory.adopt(h)
        c.nodes[0].store.put("obj-rep", bytes(4096))
        c.nodes[1].store.fetch_from(c.nodes[0].store, "obj-rep")
        assert c.gcs.locations("obj-rep") == frozenset({0, 1})
        # pressure node1 with protected (referenced, last-copy) objects
        fillers = []
        for i in range(6):
            f = core.ObjectRef(f"p{i}")
            c.memory.adopt(f)
            fillers.append(f)
            c.nodes[1].store.put(f"p{i}", bytes(4096))
        # the secondary replica was sacrificed; the primary survives
        assert not c.nodes[1].store.contains("obj-rep")
        assert c.nodes[0].store.contains("obj-rep")
        assert 0 in c.gcs.locations("obj-rep")
        del h, fillers
    finally:
        core.shutdown()


def test_eviction_never_drops_referenced_last_copy_without_lineage():
    c = core.init(num_nodes=1, workers_per_node=2, spill_threshold=4096,
                  store_capacity_bytes=8 * 1024)
    try:
        refs = [core.put(bytes(4096)) for _ in range(4)]  # 2x capacity
        # all four are referenced last copies with no lineage: protected,
        # so the store runs over capacity rather than losing data
        assert all(core.get(r) == bytes(4096) for r in refs)
        del refs
    finally:
        core.shutdown()


# ------------------------------------------------------ wipe / races (S3)


def _standalone_pair(latency=0.0):
    gcs = ControlPlane(2)
    return gcs, ObjectStore(0, gcs), ObjectStore(1, gcs,
                                                transfer_latency_s=latency)


def test_fetch_from_into_wiped_store_does_not_resurrect():
    gcs, a, b = _standalone_pair()
    a.put("x", [1, 2, 3])
    b.wipe()
    val = b.fetch_from(a, "x")       # caller still gets the value...
    assert val == [1, 2, 3]
    assert not b.contains("x")       # ...but the wiped store stays empty
    assert b.used_bytes == 0
    assert gcs.locations("x") == frozenset({0})


def test_wipe_racing_inflight_transfer_stays_empty():
    gcs, a, b = _standalone_pair(latency=0.05)
    a.put("x", bytes(1000))
    out = {}
    t = threading.Thread(
        target=lambda: out.setdefault("v", b.fetch_from(a, "x")))
    t.start()
    time.sleep(0.01)                 # transfer is mid-flight (sleeping)
    b.wipe()
    t.join(2.0)
    assert out["v"] == bytes(1000)
    assert not b.contains("x")
    assert b.used_bytes == 0
    assert 1 not in gcs.locations("x")   # location did not resurrect


def test_prefetch_into_wiped_store_keeps_locations_clean():
    gcs, a, b = _standalone_pair()
    a.put("x", 41)
    b.wipe()
    b.prefetch_from(a, "x")
    assert not b.contains("x")
    assert gcs.locations("x") == frozenset({0})
    # discard on the wiped store is a no-op, not an error
    b.discard("x")
    assert gcs.locations("x") == frozenset({0})


# --------------------------------------------------- placement + pressure


def test_mem_hint_steers_placement_to_free_store():
    c = core.init(num_nodes=2, workers_per_node=2, spill_threshold=4096,
                  store_capacity_bytes=64 * 1024)
    try:
        # node0 nearly full of protected bytes
        pins = []
        for i in range(14):
            h = core.ObjectRef(f"full{i}")
            c.memory.adopt(h)
            pins.append(h)
            c.nodes[0].store.put(f"full{i}", bytes(4096))

        @core.remote(resources={"mem": 48 * 1024})
        def big():
            from repro.core.worker import current_node
            return current_node().node_id

        assert all(core.get(big.submit()) == 1 for _ in range(4))
        del pins
    finally:
        core.shutdown()


def test_des_store_occupancy_and_eviction():
    sim = ClusterSim(4, workers_per_node=2, costs=SimCosts(),
                     store_capacity_bytes=10_000, seed=0)
    for i in range(400):
        sim.submit(SimTask(i, 1e-3, i % 4, output_bytes=500), at=0.0)
    sim.run()
    assert len(sim.finished) == 400
    assert sim.evictions > 0
    assert all(n.store_used <= 10_000 for n in sim.nodes)


def test_simcosts_calibrate_evict_from_churn(tmp_path):
    import json
    doc = {"runs": {"pr4": {
        "submit": {"p50_us": 20.0}, "gcs_put": {"p50_us": 1.0},
        "get_done": {"p50_us": 5.0}, "e2e_local": {"p50_us": 70.0},
        "churn": {"reclaim_us": {"p50_us": 40.0}},
    }}, "speedup_run": "pr4"}
    p = tmp_path / "bench.json"
    p.write_text(json.dumps(doc))
    costs = SimCosts.from_microbench(str(p))
    assert costs.evict_s == pytest.approx(40e-6)


# ------------------------------------------------------------- stress (AC)


@pytest.mark.slow  # 10k-task stress loop
def test_bounded_store_stress_10k_tasks():
    """Acceptance: per-node capacity a small fraction of total output
    bytes; 10k tasks complete correctly, resident bytes never exceed
    capacity, dropped refs are reclaimed cluster-wide, and an
    evicted-then-refetched early object reconstructs via lineage."""
    cap = 64 * 1024
    c = core.init(num_nodes=2, workers_per_node=2, spill_threshold=4096,
                  store_capacity_bytes=cap)
    try:
        n, batch = 10_000, 160          # ~10 MB of outputs vs 128 KB total
        keep = blob.submit(0, 1024)     # early ref held to the very end
        assert core.get(keep) == bytes([0]) * 1024
        peak = 0
        for start in range(0, n, batch):
            refs = [blob.submit(i) for i in range(start, start + batch)]
            vals = core.get(refs)
            for i, v in zip(range(start, start + batch), vals):
                assert v[:1] == bytes([i % 251])
                assert len(v) == 1024
            peak = max(peak, max(nd.store.used_bytes for nd in c.nodes))
            del refs, vals
        assert peak <= cap, f"resident bytes {peak} exceeded capacity {cap}"
        # cluster-wide reclamation of everything the driver dropped
        assert c.memory.quiesce(30.0)
        resident = sum(nd.store.used_bytes for nd in c.nodes)
        assert resident <= 8 * 1024, \
            f"{resident} resident bytes survived the drop"
        # the early object was long evicted; lineage brings it back
        assert core.get(keep) == bytes([0]) * 1024
        s = summarize(c.gcs)
        assert s["evictions"] > 0
        assert s["reclaims"] > 0
        assert s["bytes_freed"] > 0
    finally:
        core.shutdown()


def test_pin_accounting_matches_store_accounting():
    """Regression (PR 7): pin accounting (`sizeof`) and store
    accounting (`bytes_of`) must agree to the byte with the stored
    buffer length for array-likes — the old heuristic overheads made
    capacity math drift from real segment usage."""
    import numpy as np
    from repro.core.control_plane import ControlPlane
    from repro.core.memory import sizeof
    from repro.core.object_store import ObjectStore, SharedMemoryStore
    arr = np.arange(64 * 64, dtype=np.float32).reshape(64, 64)
    blob = b"x" * 100_000
    for cls in (ObjectStore, SharedMemoryStore):
        store = cls(0, ControlPlane(1))
        try:
            store.put("a", arr)
            store.put("b", blob)
            assert store.bytes_of("a") == arr.nbytes == sizeof(arr)
            assert store.bytes_of("b") == len(blob) == sizeof(blob)
            # the shared-memory store's large buffers are segment-backed,
            # and the payload buffer length equals the accounted bytes
            payload = store.payload_of("a")
            assert len(payload.ensure_buffer()) == arr.nbytes
        finally:
            store.close()
