"""Process execution backend: multi-process workers over the
shared-memory, zero-copy object store.

The smoke subset the backend must pass to be considered functional:
submit/get, dataflow chains, actors, compiled graphs, error + spawn
safety propagation, kill-worker recovery, and the zero-copy get()
contract (read-only views over shared segments).

Worker processes are spawned once per cluster, so each test reuses one
module-scoped cluster where possible; the failure test builds its own.
"""
import time

import numpy as np
import pytest

import repro.core as rc
from repro.core import dag
from repro.core.backends import ShmRing, dump_function
from repro.core.object_store import SEGMENT_THRESHOLD

pytestmark = pytest.mark.slow  # spawn cost: seconds per cluster


@rc.remote
def add(a, b):
    return a + b


@rc.remote
def make_array(n):
    return np.arange(n, dtype=np.float32)


@rc.remote
def total(a):
    return float(np.sum(a))


@rc.remote
def fail_with(msg):
    raise ValueError(msg)


@rc.remote
def sleepy_double(x):
    time.sleep(1.0)
    return x * 2


@rc.remote
class Accum:
    def __init__(self, start=0):
        self.n = start

    def add(self, k):
        self.n += k
        return self.n


@pytest.fixture(scope="module")
def pcluster():
    cluster = rc.init(num_nodes=2, workers_per_node=2, backend="process")
    yield cluster
    rc.shutdown()


def test_submit_get_and_chain(pcluster):
    assert rc.get(add.submit(1, 2)) == 3
    x = make_array.submit(1 << 18)          # 1 MiB: segment-backed
    y = add.submit(x, x)
    s = total.submit(y)
    assert rc.get(s) == pytest.approx(2.0 * sum(range(1 << 18)))


def test_zero_copy_readonly_view(pcluster):
    x = make_array.submit(1 << 18)
    v = rc.get(x)
    assert isinstance(v, np.ndarray)
    assert not v.flags.writeable          # views are read-only
    with pytest.raises(ValueError):
        v[0] = 1.0                        # mutation requires a put()
    # the same get() twice decodes the same payload (cached view)
    assert rc.get(x) is v


def test_small_values_inline(pcluster):
    # below SEGMENT_THRESHOLD: rides inline, still correct
    small = make_array.submit(16)
    v = rc.get(small)
    np.testing.assert_array_equal(v, np.arange(16, dtype=np.float32))
    assert 16 * 4 < SEGMENT_THRESHOLD


def test_many_tasks_all_workers(pcluster):
    refs = [add.submit(i, i) for i in range(64)]
    assert [rc.get(r) for r in refs] == [2 * i for i in range(64)]


def test_error_propagates_with_message(pcluster):
    with pytest.raises(rc.TaskError, match="boom-42"):
        rc.get(fail_with.submit("boom-42"))


def test_spawn_safety_closure_rejected(pcluster):
    @rc.remote
    def local_fn():  # a closure: not importable from a worker process
        return 1

    with pytest.raises(rc.TaskError, match="module level"):
        rc.get(local_fn.submit())


def test_actor_runs_parent_side(pcluster):
    h = Accum.submit(10)
    refs = [h.add.submit(1) for _ in range(5)]
    assert rc.get(refs[-1]) == 15


def test_compiled_graph(pcluster):
    a = add.bind(dag.input(0), 1)
    b = add.bind(a, a)
    cg = dag.compile(b)
    for i in range(3):
        assert rc.get(cg.execute(i)) == 2 * (i + 1)


def test_wait_returns_done(pcluster):
    refs = [add.submit(i, 0) for i in range(8)]
    done, pending = rc.wait(refs, num_returns=8, timeout=30)
    assert len(done) == 8 and not pending


def test_kill_worker_process_recovers():
    """A worker process dying mid-task fail-stops like a dead node:
    the in-flight task is LOST, lineage replay reruns it elsewhere, and
    the failure detector retires the degraded node."""
    cluster = rc.init(num_nodes=2, workers_per_node=2, backend="process",
                      failure_detection=True)
    try:
        r = sleepy_double.submit(21)
        deadline = time.perf_counter() + 10
        victim = None
        while victim is None and time.perf_counter() < deadline:
            for node in cluster.nodes:
                for i in range(node.backend.num_workers):
                    if node.backend._winflight[i]:
                        victim = node.backend._procs[i]
                        break
                if victim:
                    break
            time.sleep(0.02)
        assert victim is not None, "task never reached a worker"
        victim.kill()
        assert rc.get(r, timeout=60) == 42
    finally:
        rc.shutdown()


def test_shm_ring_roundtrip_and_wrap():
    ring = ShmRing(capacity=1024)
    try:
        for i in range(100):  # 100 records >> capacity: exercises wrap
            ring.push(bytes([i % 256]) * (i % 50 + 1))
            rec = ring.pop(timeout=1.0)
            assert rec == bytes([i % 256]) * (i % 50 + 1)
        assert ring.pop(timeout=0.01) is None
    finally:
        ring.close()


def test_shm_ring_rejects_oversized_record():
    ring = ShmRing(capacity=256)
    try:
        with pytest.raises(ValueError, match="exceeds ring capacity"):
            ring.push(b"x" * 512)
    finally:
        ring.close()


def test_dump_function_unwraps_remote_decorator():
    # direct pickle of the raw fn fails (the @remote wrapper owns the
    # module attribute), so dump_function ships a by-name reference;
    # loading it back must give a callable computing the same thing
    import pickle
    fn = pickle.loads(dump_function(add._fn))
    if hasattr(fn, "load"):
        fn = fn.load()
    assert fn(2, 3) == 5
