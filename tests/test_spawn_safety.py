"""Spawn safety: everything the process backend ships across a process
boundary must pickle — TaskSpecs, @remote task payloads, actor
constructor arguments — and everything that can't must fail with an
actionable error naming the offending object, not a bare PicklingError
three frames deep in multiprocessing.
"""
import pickle

import numpy as np
import pytest

import repro.core as rc
from repro.core.backends import dump_function
from repro.core.control_plane import TaskSpec
from repro.core.serialization import Payload, SpawnSafetyError


@rc.remote
def module_level_task(a, b=1):
    return a + b


@rc.remote
class ModuleLevelActor:
    def __init__(self, base, scale=2.0):
        self.base = base
        self.scale = scale

    def value(self):
        return self.base * self.scale


def test_taskspec_pickle_roundtrip():
    spec = TaskSpec(task_id="t1", func_name="module_level_task",
                    args=(1, np.float32(2.0)), kwargs={"b": 3},
                    return_ids=("o1",), resources={"cpu": 1.0},
                    submitter_node=0, max_retries=2,
                    retry_exceptions=(ValueError,), deadline_s=1.5)
    out = pickle.loads(pickle.dumps(spec, protocol=5))
    assert out.task_id == spec.task_id
    assert out.func_name == spec.func_name
    assert out.kwargs == {"b": 3}
    assert out.retry_exceptions == (ValueError,)
    assert out.deadline_s == 1.5


def test_remote_function_ships_by_name():
    """@remote rebinds the module attribute to the wrapper, which
    breaks pickle's identity check for the raw function — dump_function
    must still produce something the child can load and call."""
    blob = dump_function(module_level_task._fn)
    fn = pickle.loads(blob)
    if hasattr(fn, "load"):
        fn = fn.load()
    assert fn(2, b=3) == 5


def test_actor_ctor_payload_roundtrips():
    """Actor constructor args follow the same pickle rules as task
    args (the process backend resolves them parent-side, but the spawn
    contract — plain data or refs — must hold)."""
    args = (41,)
    kwargs = {"scale": 0.5}
    a2, k2 = pickle.loads(pickle.dumps((args, kwargs), protocol=5))
    inst_cls = ModuleLevelActor._cls
    assert inst_cls(*a2, **k2).value() == 20.5


def test_closure_error_names_the_function():
    def local_closure():  # noqa: D401 - deliberately un-importable
        return 1

    with pytest.raises(SpawnSafetyError) as ei:
        dump_function(local_closure)
    msg = str(ei.value)
    assert "local_closure" in msg          # names the offender
    assert "module level" in msg           # says how to fix it


def test_unpicklable_value_error_names_the_object():
    payload = Payload.wrap(lambda: 0)     # lambdas never pickle
    with pytest.raises(SpawnSafetyError) as ei:
        payload.ensure_buffer(strict=True)
    assert "<lambda>" in str(ei.value)
    assert "process boundary" in str(ei.value)


def test_unpicklable_is_fine_in_thread_backend():
    """The same by-reference value is legal when it never leaves the
    process: the thread store holds it RAW."""
    payload = Payload.wrap(lambda: 7)
    assert payload.ensure_buffer(strict=False) is None  # downgraded
    assert payload.value()() == 7                       # still callable


def test_example_workloads_spawn_safe():
    """The shipped examples' remote functions must be shippable to a
    worker process (module-level, importable)."""
    import importlib.util
    import pathlib
    import sys
    path = (pathlib.Path(__file__).resolve().parent.parent
            / "examples" / "rl_pipeline.py")
    spec = importlib.util.spec_from_file_location("rl_pipeline", path)
    rl_pipeline = importlib.util.module_from_spec(spec)
    sys.modules["rl_pipeline"] = rl_pipeline   # lets _ByName.load resolve
    try:
        spec.loader.exec_module(rl_pipeline)
        for fn in (rl_pipeline.simulate,):
            raw = getattr(fn, "_fn", fn)
            loaded = pickle.loads(dump_function(raw))
            if hasattr(loaded, "load"):
                loaded = loaded.load()
            assert callable(loaded) and not hasattr(loaded, "submit")
    finally:
        sys.modules.pop("rl_pipeline", None)
