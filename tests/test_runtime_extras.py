"""Additional runtime/system coverage: profiler trace dump, API options,
object-store locality, BSP/hybrid executors, wait edge cases, DES elastic
scaling, simulator latency percentiles."""
import json
import time

import pytest

from repro import core
from repro.core.executors import BSPExecutor, SerialExecutor
from repro.core.simulator import ClusterSim, SimTask


@pytest.fixture()
def cluster():
    c = core.init(num_nodes=3, workers_per_node=2)
    yield c
    core.shutdown()


def test_options_override_resources(cluster):
    @core.remote
    def f():
        return 1
    g = f.options(resources={"cpu": 2.0})
    assert core.get(g.submit()) == 1
    assert g.resources == {"cpu": 2.0}
    assert f.resources == {"cpu": 1.0}


def test_multiple_returns(cluster):
    @core.remote(num_returns=3)
    def three():
        return 1, 2, 3
    a, b, c = three.submit()
    assert core.get([a, b, c]) == [1, 2, 3]


def test_wait_num_returns_capped(cluster):
    @core.remote
    def one():
        return 1
    refs = [one.submit() for _ in range(3)]
    done, pending = core.wait(refs, num_returns=10, timeout=5.0)
    assert len(done) == 3 and not pending


def test_put_get_roundtrip_objects(cluster):
    import numpy as np
    arr = np.arange(1000)
    ref = core.put(arr)
    out = core.get(ref)
    assert (out == arr).all()


def test_object_locality_transfer(cluster):
    """get() from a worker on another node transfers + registers a copy."""
    @core.remote
    def make():
        return list(range(100))

    @core.remote
    def consume(x):
        return sum(x)

    ref = make.submit()
    core.get(ref)
    out = core.get(consume.submit(ref))
    assert out == sum(range(100))
    # after consumption the object may be resident on >= 1 node
    assert len(cluster.gcs.locations(ref.id)) >= 1


def test_chrome_trace_dump(tmp_path, cluster):
    @core.remote
    def f():
        return 1
    core.get(f.submit())
    from repro.core.profiler import dump_chrome_trace
    p = tmp_path / "trace.json"
    dump_chrome_trace(cluster.gcs, str(p))
    data = json.loads(p.read_text())
    assert len(data["traceEvents"]) > 0


def test_bsp_executor_barrier_semantics():
    ex = BSPExecutor(num_workers=4, driver_overhead_s=0.0)
    out = ex.map_stage(lambda x: x * 2, list(range(10)))
    assert out == [x * 2 for x in range(10)]
    ex.shutdown()


def test_serial_executor():
    assert SerialExecutor().map_stage(lambda x: x + 1, [1, 2]) == [2, 3]


def test_des_elastic_add_increases_throughput():
    def run(nodes_late):
        sim = ClusterSim(4, workers_per_node=2, seed=0)
        for i in range(800):
            sim.submit(SimTask(i, 5e-3, i % 4), at=0.0)
        if nodes_late:
            for _ in range(12):
                sim.add_node(2, at=0.05)
        sim.run()
        return max(t.finish_t for t in sim.finished)

    assert run(True) < run(False)


def test_des_latency_percentiles_present():
    sim = ClusterSim(4, workers_per_node=2, seed=0)
    for i in range(100):
        sim.submit(SimTask(i, 1e-3, i % 4), at=0.0)
    sim.run()
    p = sim.latency_percentiles()
    assert set(p) == {"p50", "p90", "p99"} and p["p99"] >= p["p50"]


def test_driver_roundrobin_spreads_nodes(cluster):
    @core.remote
    def where():
        from repro.core.worker import current_node
        time.sleep(0.01)
        return current_node().node_id
    refs = [where.submit() for _ in range(12)]
    assert len(set(core.get(refs))) >= 2
