"""Distribution tests: a reduced-config multi-device lower+compile in a
subprocess (8 placeholder host devices, (2,2,2) pod mesh), validating the
whole dryrun path — shardings accepted, memory/cost analysis present,
collectives parsed — without the 512-device production sweep (that runs
via `python -m repro.launch.dryrun --all`, results in EXPERIMENTS.md)."""
import json
import os
import subprocess
import sys
import textwrap
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    from functools import partial
    from repro.configs.base import ShapeConfig
    from repro.configs.registry import get_smoke_config
    from repro.models import build_model
    from repro.optim.adamw import AdamWConfig, adamw_init
    from repro.parallel.sharding import make_rules
    from repro.train.train_step import make_train_step
    from repro.analysis.hlo import analyze_hlo

    arch = %(arch)r
    mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    shape = ShapeConfig("t", %(kind)r, %(seq)d, %(batch)d)
    cfg = get_smoke_config(arch).scaled(train_microbatch=0)
    rules = make_rules(mesh, cfg, shape)
    model = build_model(cfg, rules)
    specs = model.input_specs(shape)
    in_sh = rules.input_shardings(specs)
    params_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    p_sh = rules.param_shardings(params_shapes)
    if shape.kind == "train":
        opt_shapes = jax.eval_shape(partial(adamw_init, state_dtype=cfg.opt_state_dtype), params_shapes)
        o_sh = rules.opt_shardings(opt_shapes)
        o_sh["step"] = rules.scalar_sharding()
        fn = jax.jit(make_train_step(model, AdamWConfig()),
                     in_shardings=(p_sh, o_sh, in_sh),
                     out_shardings=(p_sh, o_sh, None))
        lowered = fn.lower(params_shapes, opt_shapes, specs)
    else:
        cache_shapes = jax.eval_shape(lambda: model.init_cache(shape.global_batch, shape.seq_len))
        c_sh = rules.cache_shardings(cache_shapes)
        fn = jax.jit(model.decode_step,
                     in_shardings=(p_sh, c_sh, in_sh["tokens"], rules.scalar_sharding()),
                     out_shardings=(None, c_sh))
        lowered = fn.lower(params_shapes, cache_shapes, specs["tokens"],
                           jax.ShapeDtypeStruct((), jnp.int32))
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    hlo = analyze_hlo(compiled.as_text(), total_devices=8)
    print(json.dumps({
        "ok": True,
        "temp": mem.temp_size_in_bytes,
        "args": mem.argument_size_in_bytes,
        "flops": hlo.flops,
        "coll": hlo.collective_bytes(),
        "kinds": hlo.by_kind(),
    }))
""")


def _run(arch, kind="train", seq=64, batch=8):
    code = SCRIPT % {"arch": arch, "kind": kind, "seq": seq, "batch": batch}
    env = dict(os.environ, PYTHONPATH=str(ROOT / "src"))
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-3000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


@pytest.mark.parametrize("arch", ["stablelm-1.6b", "mixtral-8x22b",
                                  "jamba-1.5-large-398b"])
def test_multipod_train_compiles_with_collectives(arch):
    res = _run(arch, "train")
    assert res["ok"]
    assert res["flops"] > 0
    # data-parallel training must all-reduce (or reduce-scatter) gradients
    assert res["coll"] > 0, res["kinds"]


def test_multipod_decode_compiles():
    res = _run("stablelm-1.6b", kind="decode", seq=64, batch=8)
    assert res["ok"] and res["args"] > 0
