"""Infrastructure tests: checkpointing (atomicity, async, elastic restore),
trainer resume, optimizer, data prefetcher, HLO analyzer, sharding rules."""
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 4)),
            "b": {"c": jnp.arange(6, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(10, t)
    out = ck.restore(jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert ck.latest_step() == 10


def test_checkpoint_async_and_gc(tmp_path):
    ck = Checkpointer(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, _tree(s), blocking=False)
        ck.wait()
    assert ck.steps() == [3, 4]


def test_checkpoint_atomic_no_tmp_left(tmp_path):
    ck = Checkpointer(str(tmp_path))
    ck.save(5, _tree())
    assert not any(p.name.endswith(".tmp") for p in tmp_path.iterdir())


def test_checkpoint_elastic_restore_resharded(tmp_path):
    """Restore onto a different device layout (elastic scaling)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    ck = Checkpointer(str(tmp_path))
    t = _tree()
    ck.save(1, t)
    mesh = jax.make_mesh((1,), ("data",))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), t)
    out = ck.restore(jax.eval_shape(lambda: t), shardings=sh)
    assert jax.tree.leaves(out)[0].sharding == NamedSharding(mesh, P())


def test_adamw_decreases_quadratic():
    w = {"w": jnp.ones((16,)) * 5.0}
    opt = adamw_init(w)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(200):
        g = {"w": 2 * w["w"]}
        w, opt, _ = adamw_update(cfg, g, opt, w)
    assert float(jnp.max(jnp.abs(w["w"]))) < 0.3


def test_grad_clip():
    from repro.optim.adamw import clip_by_global_norm, global_norm
    t = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(t, 1.0)
    assert float(norm) == pytest.approx(200.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)


def test_prefetcher_overlaps_and_orders():
    from repro.data.pipeline import DataConfig, Prefetcher, batch_for_step
    cfg = DataConfig(vocab_size=100, seq_len=8, global_batch=2)
    pf = Prefetcher(cfg, start_step=3)
    try:
        b0 = pf.next()
        np.testing.assert_array_equal(b0["tokens"],
                                      batch_for_step(cfg, 3)["tokens"])
        b1 = pf.next()
        np.testing.assert_array_equal(b1["tokens"],
                                      batch_for_step(cfg, 4)["tokens"])
    finally:
        pf.close()


def test_hlo_analyzer_loop_awareness():
    """The analyzer must multiply while-body flops by the trip count."""
    from repro.analysis.hlo import analyze_hlo

    def body(h, w):
        return jnp.tanh(h @ w), None

    def f(h, ws):
        h, _ = jax.lax.scan(body, h, ws)
        return h

    h = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    ws = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    c = jax.jit(f).lower(h, ws).compile()
    res = analyze_hlo(c.as_text())
    expected_dot = 2 * 64 * 64 * 64 * 8  # 8 iterations
    assert res.dot_flops == pytest.approx(expected_dot, rel=0.01)
    ca = c.cost_analysis()
    if isinstance(ca, (list, tuple)):  # older JAX returns [dict]
        ca = ca[0]
    raw = ca["flops"]
    assert res.dot_flops > raw  # XLA counted the body once


def test_hlo_analyzer_collectives():
    from repro.analysis.hlo import analyze_hlo
    from jax.sharding import NamedSharding, PartitionSpec as P
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device (run under dryrun env)")


def test_sharding_rules_divisibility_never_invalid():
    """Every generated spec must divide the dim it shards."""
    from repro.configs.base import TRAIN_4K
    from repro.configs.registry import ARCH_IDS, get_config
    from repro.parallel.sharding import make_rules
    mesh = jax.make_mesh((1, 1), ("data", "model"))

    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}

    for arch in ARCH_IDS:
        cfg = get_config(arch)
        rules = make_rules(FakeMesh(), cfg, TRAIN_4K)
        # exercise the parameter rules against real shapes
        from repro.models import build_model
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))

        def check(path, leaf):
            pstr = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                            for p in path)
            spec = rules._param_spec(pstr, leaf.shape)
            for dim, axes in zip(leaf.shape, spec):
                if axes is None:
                    continue
                axes = (axes,) if isinstance(axes, str) else axes
                n = 1
                for a in axes:
                    n *= FakeMesh.shape[a]
                assert dim % n == 0, (arch, pstr, leaf.shape, spec)
            return leaf

        jax.tree_util.tree_map_with_path(check, shapes)


def test_compressing_train_step_converges():
    from repro.configs.registry import get_smoke_config
    from repro.models import build_model
    from repro.parallel.compression import (init_error_feedback,
                                            make_compressing_train_step)
    cfg = get_smoke_config("stablelm-1.6b").scaled(param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    efb = init_error_feedback(params)
    step = jax.jit(make_compressing_train_step(model, AdamWConfig(lr=2e-3),
                                               threshold_elems=0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                          cfg.vocab_size)}
    losses = []
    for _ in range(20):
        params, opt, efb, m = step(params, opt, efb, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.3, losses


def test_serving_engine_greedy_decode():
    from repro.configs.registry import get_smoke_config
    from repro.models import build_model
    from repro.serving import Request, ServingEngine
    cfg = get_smoke_config("internvl2-2b").scaled(param_dtype="float32",
                                                  input_mode="tokens",
                                                  num_image_tokens=0)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = ServingEngine(model, params, max_seq=64)
    reqs = [Request(i, np.random.default_rng(i).integers(
        1, 200, size=(16,)).astype(np.int32), max_new_tokens=4)
        for i in range(3)]
    resp = eng.serve(reqs)
    assert sorted(r.request_id for r in resp) == [0, 1, 2]
    assert all(len(r.tokens) == 4 for r in resp)
    # greedy decode is deterministic
    resp2 = eng.serve(reqs)
    assert all(a.tokens == b.tokens for a, b in
               zip(sorted(resp, key=lambda r: r.request_id),
                   sorted(resp2, key=lambda r: r.request_id)))
