"""End-to-end driver: data-parallel LM training as compiled task graphs
over a device-typed cluster (deliverable (b) + the paper's R5).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 40
      PYTHONPATH=src python examples/train_lm.py --steps 40 --shards 4
      PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --sync

Every step is ONE compiled-graph invocation over the cluster: per-shard
forward/backward kernel tasks (`kernel_task`, `{"gpu": 1}` — placed only
on the gpu-typed nodes and executed on their dedicated device lanes),
a grad-reduce graph node averaging the shard gradients, and an AdamW
apply node. The updated params/opt-state *futures* feed the next step's
execute() directly, so weights never round-trip through the driver on
the hot path; every `--publish-every` steps the driver materializes them
once and publishes a versioned `ParamSet` (sharded, zero-copy readable)
that any consumer can hot-swap from.

Uses the xlstm-125m assigned config at reduced width by default (CPU
container, Pallas kernels in interpret mode); pass --full for the real
125M config (slow on CPU, exact on TPU).
"""
import argparse
import time

import jax
import numpy as np

from repro import core, dag
from repro.compute import ParamSet, kernel_task
from repro.configs.registry import get_config, get_smoke_config
from repro.core import profiler
from repro.data.pipeline import DataConfig, batch_for_step
from repro.models import build_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update


def build_step_fns(model, opt_cfg):
    """The jitted compute payloads of one training step."""
    def shard_loss(params, batch):
        return model.loss_fn(params, batch)[0]

    grad_fn = jax.value_and_grad(shard_loss)

    def grad_shard(params, batch):
        return grad_fn(params, batch)          # (loss, grads)

    def reduce_grads(*shard_grads):
        n = float(len(shard_grads))
        return jax.tree.map(lambda *gs: sum(gs) / n, *shard_grads)

    def apply_update(params, opt_state, grads):
        params, opt_state, _ = adamw_update(opt_cfg, grads, opt_state,
                                            params)
        return params, opt_state

    return grad_shard, reduce_grads, apply_update


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--shards", type=int, default=2,
                    help="data-parallel gradient shards = gpu-typed nodes")
    ap.add_argument("--publish-every", type=int, default=10,
                    help="publish a versioned ParamSet every N steps")
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) architecture config")
    ap.add_argument("--sync", action="store_true",
                    help="single-process jit loop (no task runtime)")
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full
           else get_smoke_config(args.arch).scaled(
               num_layers=4, d_model=256, param_dtype="float32",
               vocab_size=2048))
    cfg = cfg.scaled(train_microbatch=0)
    model = build_model(cfg)
    assert args.batch % args.shards == 0, "--batch must divide --shards"
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch,
                          num_shards=args.shards,
                          input_mode=cfg.input_mode, d_model=cfg.d_model,
                          num_image_tokens=cfg.num_image_tokens)
    opt_cfg = AdamWConfig(lr=1e-3)
    grad_shard_fn, reduce_fn, apply_fn = build_step_fns(model, opt_cfg)

    params = model.init(jax.random.PRNGKey(0))
    opt_state = adamw_init(params)
    shard_cfgs = [DataConfig(**{**data_cfg.__dict__, "shard_id": s})
                  for s in range(args.shards)]

    t0 = time.perf_counter()
    losses = []
    if args.sync:
        step_fn = jax.jit(lambda p, o, *bs: (
            lambda lg: apply_fn(p, o, reduce_fn(*[g for _, g in lg]))
            + (sum(l for l, _ in lg) / len(lg),)
        )([grad_shard_fn(p, b) for b in bs]))
        for step in range(args.steps):
            shards = [batch_for_step(c, step) for c in shard_cfgs]
            params, opt_state, loss = step_fn(params, opt_state, *shards)
            losses.append((step, float(loss)))
    else:
        # one gpu-typed node per shard + one cpu node for reduce/apply
        cluster = core.init(node_resources=(
            [{"cpu": 2.0, "gpu": 1.0}] * args.shards + [{"cpu": 2.0}]))

        # forward/backward is a device kernel task: jit-warmed at
        # registration, placed only where a gpu unit exists, timed as
        # profiler "kernel" events
        warm = [batch_for_step(c, 0) for c in shard_cfgs]
        grad_shard = kernel_task(
            grad_shard_fn, resources={"gpu": 1.0}, num_returns=2,
            warmup_args=(params, warm[0]))
        reduce_grads = core.remote(reduce_fn)
        apply_update = core.remote(apply_fn, num_returns=2)

        # compile the step graph once: inputs are (params, opt_state,
        # *batch_shards); outputs are (params', opt_state', *losses)
        gs = [grad_shard.bind(dag.input(0), dag.input(2 + s))
              for s in range(args.shards)]
        red = reduce_grads.bind(*[g[1] for g in gs])
        upd = apply_update.bind(dag.input(0), dag.input(1), red)
        cg = dag.compile([upd[0], upd[1]] + [g[0] for g in gs])

        params_ref = core.put(params)
        opt_ref = core.put(opt_state)
        for step in range(args.steps):
            shards = [batch_for_step(c, step) for c in shard_cfgs]
            refs = cg.execute(params_ref, opt_ref, *shards)
            params_ref, opt_ref = refs[0], refs[1]
            loss = float(np.mean([np.asarray(v)
                                  for v in core.get(list(refs[2:]),
                                                    timeout=120)]))
            losses.append((step, loss))
            if args.publish_every and (step + 1) % args.publish_every == 0:
                ps = ParamSet.publish(
                    "lm", core.get(params_ref, timeout=120),
                    num_shards=args.shards)
                print(f"  step {step:3d}: published ParamSet lm@v"
                      f"{ps.version} ({ps.total_bytes / 1e6:.1f} MB, "
                      f"{len(ps.shard_ids)} shards)")
        stats = profiler.summarize(cluster.gcs)
        print(f"kernel tasks: {stats['kernel_tasks']:.0f}, mean on-device "
              f"{stats['kernel_time_ms_mean']:.1f} ms, device waits "
              f"{stats['device_waits']:.0f}, param publishes "
              f"{stats['param_publishes']:.0f}")
        core.shutdown()
    dt = time.perf_counter() - t0

    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq_len / dt:.0f} tok/s)")
    print("loss curve:", [(s, round(l, 3))
                          for s, l in losses[:: max(1, len(losses)//8)]])
    first, last = losses[0][1], losses[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
