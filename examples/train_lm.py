"""End-to-end driver: train a ~125M-parameter LM for a few hundred steps
through the fault-tolerant async pipeline (deliverable (b) end-to-end).

Run:  PYTHONPATH=src python examples/train_lm.py --steps 200
      PYTHONPATH=src python examples/train_lm.py --steps 200 --kill-node
      PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m --sync

Uses the xlstm-125m assigned config at reduced width by default (CPU
container); pass --full for the real 125M config (slow on CPU, exact on
TPU). Checkpoints + resume + node-kill fault injection included.
"""
import argparse
import threading
import time

import jax

from repro import core
from repro.configs.registry import get_config, get_smoke_config
from repro.data.pipeline import DataConfig
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train.trainer import AsyncTrainer, Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--full", action="store_true",
                    help="use the full (not reduced) architecture config")
    ap.add_argument("--sync", action="store_true",
                    help="plain synchronous Trainer (no task runtime)")
    ap.add_argument("--kill-node", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args()

    cfg = (get_config(args.arch) if args.full
           else get_smoke_config(args.arch).scaled(
               num_layers=4, d_model=256, param_dtype="float32",
               vocab_size=2048))
    cfg = cfg.scaled(train_microbatch=0)
    model = build_model(cfg)
    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                          global_batch=args.batch,
                          input_mode=cfg.input_mode, d_model=cfg.d_model,
                          num_image_tokens=cfg.num_image_tokens)
    tcfg = TrainerConfig(steps=args.steps, checkpoint_every=50,
                         checkpoint_dir=args.ckpt_dir, log_every=20,
                         opt=AdamWConfig(lr=1e-3))

    t0 = time.perf_counter()
    if args.sync:
        out = Trainer(model, data_cfg, tcfg).run()
    else:
        cluster = core.init(num_nodes=3, workers_per_node=2)
        for n in cluster.nodes:
            n.capacity["tpu"] = 1.0
            n._avail["tpu"] = 1.0
        if args.kill_node:
            threading.Timer(3.0, lambda: cluster.kill_node(2)).start()
        out = AsyncTrainer(model, data_cfg, tcfg,
                           backup_tasks=True).run()
        core.shutdown()
    dt = time.perf_counter() - t0

    losses = out["losses"]
    print(f"\ntrained {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq_len / dt:.0f} tok/s)")
    print("loss curve:", [(s, round(l, 3)) for s, l in losses[:: max(1, len(losses)//8)]])
    first, last = losses[0][1], losses[-1][1]
    print(f"loss {first:.3f} -> {last:.3f} ({'improved' if last < first else 'NOT improved'})")
    return 0 if last < first else 1


if __name__ == "__main__":
    raise SystemExit(main())
