"""Serve a small LM through the open-loop front door: seeded Poisson
arrivals land on their own clock, admission control bounds the queue,
expired requests are shed before dispatch (EDF), the AIMD controller
adapts the wave size to the engine's measured latency, and the
autoscaler grows/reclaims replica actors under queue pressure — the
paper's R1/R2 shape applied end-to-end to LLM serving.

Requests are submitted with a per-request deadline; the run ends with
the SLO tracker's disposition ledger (ok/late/shed/rejected), sliding
latency percentiles, and goodput.

Run:  PYTHONPATH=src python examples/serve_llm.py --rate 20 --duration 3
"""
import argparse

import jax

from repro import core
from repro.configs.registry import get_smoke_config
from repro.models import build_model
from repro.serving import FrontDoor, ServingEngine
from repro.serving import load as serving_load
from repro.serving.frontdoor import AdmissionError, DeadlineShedError


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--rate", type=float, default=20.0,
                    help="mean open-loop arrival rate (req/s)")
    ap.add_argument("--duration", type=float, default=3.0)
    ap.add_argument("--deadline-ms", type=float, default=2000.0)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).scaled(param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = max(serving_load.LENGTH_BUCKETS) + args.max_new + 4

    core.init(num_nodes=2, workers_per_node=2)

    # each replica actor builds its own engine on its node (model state
    # never round-trips through the object store); the front door owns
    # admission, deadline shedding, batching, and autoscaling above them
    max_batch = 2

    def warm_engine():
        # runs inside each replica actor's constructor: pre-compile every
        # (wave width, prompt length) shape the trace can produce, so no
        # cold jit blows deadlines once the open-loop clock starts
        import numpy as np
        from repro.serving import Request
        eng = ServingEngine(model, params, max_seq=max_seq)
        for plen in serving_load.LENGTH_BUCKETS:
            for width in range(1, max_batch + 1):
                reqs = [Request(0, np.arange(plen, dtype=np.int32) % 7 + 1,
                                max_new_tokens=2) for _ in range(width)]
                eng.serve(reqs, max_wave=width)
        return eng

    # fixed fleet: the example demonstrates the open-loop SLO path;
    # autoscaling under load is exercised by benchmarks/serve_bench.py
    fd = FrontDoor(
        warm_engine,
        num_replicas=args.replicas, min_replicas=args.replicas,
        max_replicas=args.replicas,
        default_deadline_s=args.deadline_ms / 1e3,
        target_wave_s=0.5 * args.deadline_ms / 1e3,
        max_batch=max_batch, resources={"cpu": 0.25})

    # readiness probes: replica constructors (and their jit warmup) run
    # asynchronously — don't start the arrival clock until every replica
    # has served a round
    probe_trace = [(0.0, serving_load.LENGTH_BUCKETS[0], args.max_new)
                   ] * (2 * args.replicas)
    probes = serving_load.materialize(probe_trace, seed=args.seed,
                                      vocab=cfg.vocab_size - 1)
    for t in [fd.submit_request(r, deadline_s=600.0) for _, r in probes]:
        t.result(timeout=600)

    trace = serving_load.poisson_trace(args.rate, args.duration,
                                       seed=args.seed,
                                       max_new_tokens=args.max_new)
    reqs = serving_load.materialize(trace, seed=args.seed,
                                    vocab=cfg.vocab_size - 1)
    tickets = []

    def submit(req):
        try:
            tickets.append(fd.submit_request(req))
        except AdmissionError:
            pass                           # counted by the SLO tracker

    # open loop: replay submits on the trace's clock and never waits on
    # completions — the system keeps up or the ledger shows it didn't
    offered = serving_load.replay(reqs, submit)

    ok = shed = 0
    for t in tickets:
        try:
            t.result(timeout=120)
            ok += 1
        except (DeadlineShedError, core.TaskError, TimeoutError):
            shed += 1
    st = fd.stats()
    print(f"offered {offered} req @ {args.rate:.0f}/s open-loop, "
          f"deadline {args.deadline_ms:.0f}ms")
    print(f"  admitted={st['admitted']} rejected={st['rejected']} "
          f"ok={st['completed_ok']} late={st['completed_late']} "
          f"shed={st['shed']}")
    print(f"  latency p50={st['latency_p50_ms']:.1f}ms "
          f"p99={st['latency_p99_ms']:.1f}ms "
          f"goodput={fd.slo.overall_goodput():.1f}/s")
    print(f"  replicas={st['replicas']} batch_limits={st['batch_limits']} "
          f"dispatched_past_deadline={st['dispatched_past_deadline']}")
    fd.close()
    core.shutdown()
    assert ok + shed == len(tickets)
    assert st["dispatched_past_deadline"] == 0
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
