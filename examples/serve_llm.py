"""Serve a small LM through the actor-backed replica pool: async request
admission (futures), an N-replica actor serving tier with wait-based
straggler routing, wave-batched prefill+decode per replica — the paper's
R1/R2 shape applied to LLM serving, now with stateful serving actors.

Run:  PYTHONPATH=src python examples/serve_llm.py --requests 12
"""
import argparse
import time

import jax
import numpy as np

from repro import core
from repro.configs.registry import get_smoke_config
from repro.models import build_model
from repro.serving import ReplicaPool, Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch).scaled(param_dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.max_new + 4

    cluster = core.init(num_nodes=2, workers_per_node=2)

    # each replica actor builds its own engine on its node (model state
    # never round-trips through the object store)
    pool = ReplicaPool(lambda: ServingEngine(model, params, max_seq=max_seq),
                       num_replicas=args.replicas)

    @core.remote
    def make_request(i):
        rng = np.random.default_rng(i)
        return Request(i, rng.integers(1, cfg.vocab_size - 1,
                                       size=(args.prompt_len,)).astype(np.int32),
                       max_new_tokens=args.max_new)

    # async admission: requests arrive as futures; waves dispatch to the
    # least-loaded replica as they fill, results stream back via wait()
    req_refs = [make_request.submit(i) for i in range(args.requests)]
    wave_refs = []
    pending = req_refs
    while pending:
        done, pending = core.wait(pending, num_returns=min(4, len(pending)),
                                  timeout=5.0)
        wave_refs.append(pool.submit_wave(core.get(done)))
    t0 = time.perf_counter()
    responses = [r for ref in wave_refs for r in core.get(ref, timeout=120)]
    wall = time.perf_counter() - t0

    responses.sort(key=lambda r: r.request_id)
    n_tok = sum(len(r.tokens) for r in responses)
    print(f"served {len(responses)} requests, {n_tok} tokens "
          f"on {args.replicas} replica actors")
    lat = sorted(r.latency_s for r in responses)
    print(f"latency p50={lat[len(lat)//2]*1e3:.1f}ms "
          f"p99={lat[-1]*1e3:.1f}ms")
    for i, st in enumerate(pool.stats()):
        print(f"  replica {i}: {st['waves_served']} waves, "
              f"{st['requests_served']} requests")
    for r in responses[:3]:
        print(f"  req {r.request_id}: {r.tokens}")
    core.shutdown()
    assert len(responses) == args.requests
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
