"""Quickstart: the paper's programming model in 60 lines.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import time

import numpy as np

from repro import core


def main():
    cluster = core.init(num_nodes=4, workers_per_node=2)

    # -- 1. arbitrary functions become remote tasks (R4); creation is
    #       non-blocking and returns futures (R3)
    @core.remote
    def rollout(seed):
        rng = np.random.default_rng(seed)
        time.sleep(0.01 * rng.random())              # heterogeneous tasks
        return rng.standard_normal(4)

    @core.remote
    def reduce_mean(*chunks):
        return np.mean(np.stack(chunks), axis=0)

    # -- 2. futures as arguments build an arbitrary DAG (R5)
    futures = [rollout.submit(i) for i in range(16)]
    total = reduce_mean.submit(*futures)
    print("mean of 16 rollouts:", core.get(total).round(3))

    # -- 3. wait() gives latency-budgeted dynamic control flow (R1):
    #       act on whatever finished within 8 ms, leave stragglers running
    futures = [rollout.submit(100 + i) for i in range(16)]
    done, pending = core.wait(futures, num_returns=16, timeout=0.008)
    print(f"after 8ms: {len(done)} done, {len(pending)} stragglers")

    # -- 4. compiled graphs: the same DAG shape replayed at high rate
    #       pays ONE batched control-plane round per invocation instead
    #       of one per task — bind() builds the graph lazily, compile()
    #       plans it once, execute() replays it with fresh inputs
    from repro import dag
    rollouts = [rollout.bind(dag.input(i)) for i in range(4)]
    step = dag.compile(reduce_mean.bind(*rollouts))
    for gen in range(2):
        ref = step.execute(*(200 + 100 * gen + s for s in range(4)))
        print(f"compiled gen {gen}:", core.get(ref).round(3))

    # -- 5. transparent fault tolerance (R6): kill the node holding a
    #       result; lineage replay reconstructs it on get()
    ref = rollout.submit(7)
    val = core.get(ref)
    for node_id in cluster.gcs.locations(ref.id):
        cluster.kill_node(node_id)
    val2 = core.get(ref)                              # replayed
    print("survived node failure:", np.allclose(val, val2))

    # -- 6. profiling (R7): every transition is in the control plane
    from repro.core.profiler import summarize
    print({k: round(v, 1) for k, v in summarize(cluster.gcs).items()})
    core.shutdown()


if __name__ == "__main__":
    main()
