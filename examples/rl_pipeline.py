"""The paper's motivating example (Fig. 1b / §2): an RL loop where parallel
simulations feed policy updates, built on futures + wait + a stateful
policy actor, with optional fault injection.

Run:  PYTHONPATH=src python examples/rl_pipeline.py [--kill-node] [--eager]

A tiny REINFORCE-style agent learns a bandit-ish task. The policy lives in
a `PolicyLearner` *actor*: rollout batches stream into `update` method
calls (ordered method futures — updates apply in submission order even
though nothing blocks), and each generation of simulations takes the
latest `weights()` *future* as its argument, so the dataflow graph wires
actor state straight into downstream tasks. Rollouts are remote CPU tasks
(heterogeneous durations) consumed in completion order (wait), so
stragglers never stall the learner; `--kill-node` may land on the
learner's node, in which case the actor restarts elsewhere and replays
its update log (or restores its `__getstate__` checkpoint).

The hot loop runs as a *compiled graph* by default: the per-iteration
shape — `update(batch)` then `weights()` then a generation of
`simulate(w, seed)` fan-out — is bound once (`bind`), compiled once
(`dag.compile`), and replayed every iteration (`cg.execute(batch,
*seeds)`), so each step pays ONE batched control-plane registration
instead of one round per task. `--eager` runs the original
submit-per-task loop for comparison; both train the same policy.

The fleet is heterogeneous (`node_resources=`): two nodes declare a
"gpu" unit and two are cpu-only. The learner actor requests
`{"gpu": 1}` via `.options()`, so it lands only on a device-typed node
(and can still fail over: the second gpu node catches the actor
restart under `--kill-node`), while rollouts stay on the cpu fleet.
Every 10 iterations the driver publishes the current policy as a
versioned `ParamSet` — the weight hot-swap handle an external serving
tier would poll — and verifies the zero-copy fetch round-trips.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import core, dag
from repro.compute import ParamSet


def make_policy():
    @jax.jit
    def act(w, obs):
        h = jnp.tanh(obs @ w["w1"])
        return jnp.tanh(h @ w["w2"])

    @jax.jit
    def update(w, obs, actions, rewards):
        def loss(w):
            pred = jnp.tanh(jnp.tanh(obs @ w["w1"]) @ w["w2"])
            adv = rewards - rewards.mean()
            return -jnp.mean(jnp.sum(pred * actions, -1) * adv)
        g = jax.grad(loss)(w)
        return jax.tree.map(lambda p, gg: p - 0.05 * gg, w, g)

    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    w = {"w1": jax.random.normal(k1, (8, 32)) * 0.3,
         "w2": jax.random.normal(k2, (32, 2)) * 0.3}
    return w, act, update


@core.remote(checkpoint_interval=8)
class PolicyLearner:
    """Stateful policy owner: consumes rollout batches, emits weights."""

    def __init__(self):
        self.w, self._act, self._update = make_policy()
        self.updates = 0

    def update(self, batch):
        if not batch:   # a wait() timeout can hand us an empty batch
            return 0.0
        obs = jnp.stack([b[0] for b in batch])
        acts = jnp.stack([b[1] for b in batch])
        rews = jnp.array([b[2] for b in batch])
        self.w = self._update(self.w, obs, acts, rews)
        self.updates += 1
        return float(rews.mean())

    def weights(self):
        return jax.tree.map(np.asarray, self.w)

    def __getstate__(self):
        return {"w": jax.tree.map(np.asarray, self.w),
                "updates": self.updates}

    def __setstate__(self, state):
        _, self._act, self._update = make_policy()
        self.w = jax.tree.map(jnp.asarray, state["w"])
        self.updates = state["updates"]


@core.remote
def simulate(w_host, seed):
    """Environment rollout (numpy 'physics'): reward is higher when the
    policy's action aligns with a hidden direction of the observation."""
    rng = np.random.default_rng(seed)
    time.sleep(0.002 + 0.004 * rng.random())
    obs = rng.standard_normal(8).astype(np.float32)
    h = np.tanh(obs @ w_host["w1"])
    action = np.tanh(h @ w_host["w2"])
    target = np.array([np.sign(obs[:4].sum()), np.sign(obs[4:].sum())],
                      dtype=np.float32)
    reward = float(action @ target)
    return obs, action, reward


#: Fresh simulations launched per training step by the compiled loop —
#: the fixed fan-out the step graph is compiled for.
SIMS_PER_STEP = 8


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--kill-node", action="store_true")
    ap.add_argument("--iters", type=int, default=30)
    ap.add_argument("--eager", action="store_true",
                    help="submit-per-task hot loop (the compiled-graph "
                         "loop is the default)")
    args = ap.parse_args()

    # heterogeneous fleet: two gpu-typed nodes (learner placement +
    # failover target), two cpu-only rollout nodes
    cluster = core.init(node_resources=[{"cpu": 2.0, "gpu": 1.0}] * 2
                        + [{"cpu": 2.0}] * 2)
    learner = PolicyLearner.options(
        resources={"cpu": 1.0, "gpu": 1.0}).submit()

    # compiled step: the whole per-iteration graph — update the policy
    # with this step's batch, read the post-update weights (ordered
    # method futures: the seq block guarantees update-before-weights),
    # and fan a fresh generation of simulations off the weights future.
    # Compiled once; every iteration is one epoch-tagged execute().
    step = None
    if not args.eager:
        upd = learner.update.bind(dag.input(0))
        w = learner.weights.bind()
        sims = [simulate.bind(w, dag.input(1 + i))
                for i in range(SIMS_PER_STEP)]
        step = dag.compile([upd] + sims)

    returns = []
    # the weights *future* feeds simulations directly — actor state as a
    # dataflow dependency, no copy through the driver
    w_ref = learner.weights.submit()
    pending = [simulate.submit(w_ref, s) for s in range(16)]
    for it in range(args.iters):
        if args.kill_node and it == args.iters // 2:
            victim = cluster.gcs.actor_node(learner.actor_id)
            cluster.kill_node(victim)
            print(f"!! killed node {victim} (the learner's node) "
                  "mid-training — actor replay + lineage active")
        # consume in completion order; update on partial batches (R1).
        # A rollout may resolve to a *typed error* under --kill-node
        # (e.g. its weights arg was lost past the actor's checkpoint and
        # cannot be replayed) — skip it, the learner trains on whatever
        # survived, which is exactly the paper's straggler/failure story
        batch = []
        while pending and len(batch) < 12:
            done, pending = core.wait(pending,
                                      num_returns=min(4, len(pending)),
                                      timeout=0.5)
            for r in done:
                try:
                    batch.append(core.get(r))
                except core.TaskError:
                    pass
        if step is not None:
            # one batched dispatch for update + weights + the whole
            # next generation; sink refs are ordinary futures
            refs = step.execute(tuple(batch),
                                *(1000 * it + s
                                  for s in range(SIMS_PER_STEP)))
            ret_ref = refs[0]
            pending += refs[1:]
        else:
            # eager comparison loop: one control-plane round per task
            ret_ref = learner.update.submit(tuple(batch))
            w_ref = learner.weights.submit()
            pending += [simulate.submit(w_ref, 1000 * it + s)
                        for s in range(16 - len(pending))]
        try:
            returns.append(core.get(ret_ref, timeout=30))
        except core.TaskError:
            pass   # an unreplayable update under --kill-node: skip it
        if it % 5 == 0 or it == args.iters - 1:
            print(f"iter {it:3d}  mean return {np.mean(returns[-5:]):+.3f}")
        if it % 10 == 9:
            # versioned weight hot-swap handle for external consumers
            w_now = core.get(learner.weights.submit(), timeout=30)
            ps = ParamSet.publish("policy", w_now)
            print(f"iter {it:3d}  published ParamSet policy@v{ps.version}"
                  f" ({ps.total_bytes} bytes)")

    latest = ParamSet.latest("policy") if args.iters >= 10 else None
    if latest is not None:
        fetched = latest.fetch()
        ok = all(np.array_equal(np.asarray(w_now[k]), fetched[k])
                 for k in w_now)
        print(f"ParamSet policy@v{latest.version} fetch round-trip: "
              f"{'ok' if ok else 'MISMATCH'}")

    improved = np.mean(returns[-5:]) > np.mean(returns[:5])
    mode = "eager" if args.eager else "compiled"
    print(f"policy improved: {improved} ({len(returns)} {mode} updates "
          "applied)")
    core.shutdown()
    return 0 if improved else 1


if __name__ == "__main__":
    raise SystemExit(main())
